"""Vectorized federated round engine (see docs/round_engine.md).

One engine serves both Algorithm 1 (homogeneous) and Algorithm 3
(heterogeneous prototypes).  A round decomposes into four explicit,
individually-resumable phases that round *drivers* (``repro.drivers``)
compose:

  ``sample_cohort``   draw the round's active client set (the ONLY phase
                      that advances the host rng, so completed rounds can
                      be replayed draw-for-draw on resume);
  ``train_clients``   train every prototype group's clients in ONE jitted
                      vmap-over-clients scan (``client.make_batched_local_
                      update``) — batches stacked to [K_g, n_steps, B, ...],
                      FedProx / quantize / DP inside the jit, optionally
                      the client axis sharded over a device mesh;
  ``aggregate``       optional drop-worst filter + dispatch of the stacks
                      to the configured :class:`ServerStrategy`
                      (``core/strategies.py`` registry) -> new globals;
  ``evaluate_round``  test/val accuracy per prototype -> ``RoundLog``.

Batch building (``build_round_batches``) is split out of ``train_clients``
because it is a pure host-side function of ``(round, cohort)`` — the
async-pipelined driver prefetches it rounds ahead without touching the
trajectory.  Clients with fewer local steps than the padded scan length
are masked, so each trajectory matches the sequential reference path
exactly; scan lengths and client-axis sizes are fixed per run, so the
compile count stays bounded for the whole run instead of one program per
client per distinct shape.

``FLConfig.bucketing`` (docs/bucketing.md) splits each prototype group
into a small fixed set of step-count buckets, one cached ``vmap(scan)``
per (prototype, bucket): on skewed Dirichlet splits this removes most of
the masked no-op padding steps without changing any trajectory —
bucketing only regroups the vmap axis, the per-client math is identical.
The same fixed per-bucket client capacities, padded up to mesh
divisibility, are what let HETEROGENEOUS cohorts shard their client axis
over a device mesh (``attach_mesh`` / the ``multihost`` driver).

:func:`run_rounds` keeps the historic flat API: it builds a
:class:`RoundEngine` and hands it to a driver from the registry
(``repro.drivers``; the default ``sync`` driver IS the historic loop,
extracted — trajectories are pinned bit-identical in
``tests/test_drivers.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feddf as feddf_mod
from repro.core.client import (assign_buckets, bucket_capacities,
                               build_bucketed_batches, evaluate,
                               make_batched_local_update, n_local_steps)
from repro.common.options import BUCKET_KINDS
from repro.common.pytree import tree_cat, tree_isfinite, tree_take
from repro.core.dropworst import drop_worst_stacked
from repro.core.nets import Net
from repro.core.strategies import GroupRound, RoundContext, get_strategy
from repro.data.distill_sources import DistillSource
from repro.data.synthetic import Dataset
from repro.obs import trace as _trace
from repro.optim.optimizers import Optimizer, sgd
from repro.dist.config import DistConfig
from repro.population.config import FaultConfig, PopulationConfig


def _spanned(name: str):
    """Wrap a phase method in a flight-recorder span.  Free while
    disarmed (one module-global ``is None`` check); armed, the span is
    stamped with the driver's step index as ``round=`` — the actual
    round for sync/async drivers, the WAVE number when buffered_async
    trains inside a fill wave (see docs/observability.md)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapped(self, t, *args, **kwargs):
            if _trace.recorder() is None:
                return fn(self, t, *args, **kwargs)
            with _trace.span(name, round=int(t)):
                return fn(self, t, *args, **kwargs)
        return wrapped
    return deco

# distinguishes "no init_state passed" from a legitimately-None state
# (most strategies keep no server state at all)
_UNSET = object()


@dataclasses.dataclass
class BucketConfig:
    """Step-count bucketing of the client axis (docs/bucketing.md).

    ``kind``: ``none`` (pad every client of a group to the group maximum —
    the historic path), ``pow2`` (power-of-two scan capacities) or
    ``quantile`` (capacities at step-count quantiles).  ``max_buckets``
    bounds the compile count: per run the engine compiles at most
    ``buckets x prototypes`` client-update programs
    (``core.client.CLIENT_COMPILES`` pins this in tests).  Bucketing
    never changes a trajectory — it only regroups the vmap axis."""

    kind: str = "none"        # none | pow2 | quantile
    max_buckets: int = 4


@dataclasses.dataclass
class FLConfig:
    rounds: int = 20
    client_fraction: float = 0.4  # C
    local_epochs: int = 20        # E
    local_batch_size: int = 32
    local_lr: float = 0.1
    strategy: str = "fedavg"      # any name in the strategy registry
    prox_mu: float = 0.01
    server_momentum: float = 0.3  # beta for fedavgm
    drop_worst: bool = False
    seed: int = 0
    local_optimizer: str = "sgd"  # sgd | adam (Table 6 ablation)
    local_adam_lr: float = 1e-3   # adam local lr (sgd uses local_lr)
    quantize: Optional[Callable] = None
    fusion: feddf_mod.FusionConfig = dataclasses.field(
        default_factory=feddf_mod.FusionConfig)
    feddf_init_from: str = "average"  # Table 5 ablation: average | previous
    target_accuracy: Optional[float] = None  # stop early when reached
    # client-level DP on uploads (paper §3 privacy extension; core/privacy.py)
    dp_clip: Optional[float] = None
    dp_noise_multiplier: float = 0.0
    # step-count bucketing of the client axis (docs/bucketing.md)
    bucketing: BucketConfig = dataclasses.field(default_factory=BucketConfig)
    # population / traffic / sampler axis (docs/population.md); the
    # defaults reproduce the classic fixed-roster uniform draw bit-for-bit
    population: PopulationConfig = dataclasses.field(
        default_factory=PopulationConfig)
    # fault injection + defenses (docs/robustness.md); all-zero rates
    # disable every fault path, keeping historic trajectories bit-identical
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    # per-side trim fraction for the trimmed_mean strategy
    trim_frac: float = 0.2
    # distributed fusion-pod / client-pod runtime (docs/distributed.md);
    # only the "distributed" driver reads this
    dist: DistConfig = dataclasses.field(default_factory=DistConfig)


@dataclasses.dataclass
class RoundLog:
    round: int
    test_acc: float
    val_acc: float
    ensemble_acc: Optional[float] = None
    pre_distill_acc: Optional[float] = None
    distill_steps: int = 0
    n_participants: int = 0
    n_dropped: int = 0
    # teacher batch-forwards this round's fusion cost (0 when the shared
    # logit bank served a group, or for non-distillation strategies)
    teacher_forwards: int = 0
    # how the fusion sourced its teacher logits this round: "bank" (built),
    # "bank_reused" (persistent bank hit), "on_the_fly", or
    # "skipped_small_run" (the auto heuristic predicted too few distill
    # steps to amortize a bank build); "" for non-distillation strategies
    bank: str = ""
    # the bank's storage dtype ("float32" | "bfloat16" | "int8" |
    # "fp8_e4m3") and device bytes (quantized rows + per-row scales) —
    # the observable memory the quantized dtypes shrink; ""/0 when no
    # bank served this round
    bank_dtype: str = ""
    bank_nbytes: int = 0
    # population telemetry (buffered_async driver; docs/population.md).
    # Defaults keep pre-population checkpoints loadable via RoundLog(**d).
    staleness_hist: Optional[List[int]] = None  # uploads fused at age s
    buffer_fill: int = 0          # ready-but-unconsumed uploads after agg
    n_straggling: int = 0         # in-flight uploads not yet arrived
    n_dropped_uploads: int = 0    # uploads lost to dropout since last agg
    n_stale_dropped: int = 0      # uploads discarded as > max_staleness
    eff_participants: float = 0.0  # sum of (1+s)^-a importance weights
    # fault telemetry (docs/robustness.md).  Defaults keep pre-fault
    # checkpoints loadable via RoundLog(**d).
    n_corrupted: int = 0          # uploads a fault fired on this round
    n_quarantined: int = 0        # uploads rejected by screening
    n_retries: int = 0            # re-dispatch attempts after rejection
    n_teachers_filtered: int = 0  # teachers dropped by consensus filter
    fused: bool = True            # False when quorum skipped aggregation
    rolled_back: bool = False     # non-finite globals restored to last-good
    # distributed wire telemetry (docs/distributed.md).  Defaults keep
    # pre-dist checkpoints loadable via RoundLog(**d).
    wire_bytes_up: int = 0        # accepted UPLOAD frame bytes this round
    wire_bytes_down: int = 0      # TRAIN frame bytes dispatched this round
    n_wire_retries: int = 0       # TRAIN re-dispatches (deadline/CRC)
    n_crc_failures: int = 0       # frames rejected by checksum
    n_deadline_misses: int = 0    # uploads past their per-attempt deadline
    n_wire_lost: int = 0          # clients lost at the wire layer
    n_pods_alive: int = 0         # live client pods at round end


@dataclasses.dataclass
class FLResult:
    logs: List[RoundLog]
    global_params: dict
    rounds_to_target: Optional[int] = None

    @property
    def final_acc(self) -> float:
        return self.logs[-1].test_acc if self.logs else 0.0

    @property
    def best_acc(self) -> float:
        return max(l.test_acc for l in self.logs) if self.logs else 0.0


def _make_opt(cfg: FLConfig) -> Optimizer:
    if cfg.local_optimizer == "adam":
        from repro.optim.optimizers import adam
        return adam(cfg.local_adam_lr)
    return sgd(cfg.local_lr)


@dataclasses.dataclass
class BucketBatch:
    """One (prototype, step-bucket)'s stacked scan inputs.  With bucketing
    disabled a group has exactly one of these, padded to the group-wide
    maximum — the historic layout."""

    pos: np.ndarray              # positions into RoundBatches.ks
    xb: np.ndarray               # [cap_clients, cap_steps, B, ...]
    yb: np.ndarray               # [cap_clients, cap_steps, B]
    step_mask: np.ndarray        # [cap_clients, cap_steps]
    dp_keys: np.ndarray          # [cap_clients, 2]
    k_real: int                  # un-padded client count
    cap_steps: int               # the bucket's fixed scan length

    @property
    def cap_clients(self) -> int:
        return int(self.xb.shape[0])


@dataclasses.dataclass
class RoundBatches:
    """One prototype group's host-built round inputs (pure function of
    ``(round, cohort)`` — prefetchable), split over the run-fixed step
    buckets."""

    ks: List[int]                # active client ids of this group
    buckets: List[BucketBatch]
    k_real: int                  # un-padded client count over all buckets
    weights: np.ndarray          # [k_real] local dataset sizes, in ks order
    # padding-waste accounting (benchmarks/round_engine_bench.py):
    real_steps: int              # unmasked client-steps this group runs
    padded_slots: int            # sum of cap_clients * cap_steps over buckets


class RoundEngine:
    """The per-round phases plus the precomputed run-wide state (compiled
    client updates, fixed scan lengths, device-resident eval sets).

    Drivers own the loop: which rounds run, in what order client training
    overlaps fusion, and when checkpoints fire.  The engine owns the math:
    every phase is a deterministic function of its inputs, so any driver
    that feeds the same inputs produces the same trajectory.
    """

    def __init__(
        self,
        nets: List[Net],
        client_proto: Sequence[int],
        train: Dataset,
        parts: Sequence[np.ndarray],
        val: Dataset,
        test: Dataset,
        cfg: FLConfig,
        *,
        source: Optional[DistillSource] = None,
        heterogeneous: bool = False,
        mesh=None,
        client_axis: str = "data",
    ):
        self.nets = nets
        self.client_proto = list(client_proto)
        self.train = train
        self.parts = parts
        self.val = val
        self.test = test
        self.cfg = cfg
        self.source = source
        self.heterogeneous = heterogeneous
        self.mesh = mesh
        self.client_axis = client_axis

        if cfg.bucketing.kind not in BUCKET_KINDS:
            raise ValueError(
                f"bucketing.kind must be one of {BUCKET_KINDS}, got "
                f"{cfg.bucketing.kind!r}")
        self.strategy = get_strategy(cfg.strategy)
        self.n_clients = len(parts)
        self.n_active = max(1, int(round(cfg.client_fraction
                                         * self.n_clients)))
        self.n_proto = len(nets)
        # fixed scan lengths AND fixed client-axis sizes per (prototype,
        # step-bucket) -> a bounded compile count for the whole run (group
        # sizes vary round to round in the heterogeneous case; padded
        # clients get an all-False step mask and are sliced off afterwards).
        # All of this is a pure function of the STATIC per-client dataset
        # sizes, so the bucket structure never changes across rounds.
        self.client_steps = [
            n_local_steps(len(parts[k]), cfg.local_batch_size,
                          cfg.local_epochs)
            for k in range(self.n_clients)]
        self.steps_cap = [
            max([self.client_steps[k] for k in range(self.n_clients)
                 if self.client_proto[k] == p] or [1])
            for p in range(self.n_proto)]
        proto_counts = [sum(1 for q in self.client_proto if q == p)
                        for p in range(self.n_proto)]
        self.k_cap = [min(self.n_active, c) if c else 1
                      for c in proto_counts]
        # per-prototype bucket capacities + bucket population counts (the
        # static client -> bucket assignment itself is recomputed from the
        # same step counts inside build_bucketed_batches each round)
        self.bucket_caps, self._bucket_counts = [], []
        for p in range(self.n_proto):
            steps_p = [self.client_steps[k] for k in range(self.n_clients)
                       if self.client_proto[k] == p]
            caps = bucket_capacities(steps_p or [1], cfg.bucketing.kind,
                                     cfg.bucketing.max_buckets)
            self.bucket_caps.append(caps)
            self._bucket_counts.append(np.bincount(
                assign_buckets(steps_p, caps) if steps_p else [],
                minlength=len(caps)))
        self.batch_seed_mult = 99991 if heterogeneous else 100_003
        # population / scheduler seam (docs/population.md): cohort draws
        # go through a pluggable sampler bound to run-fixed population
        # facts.  The default (uniform sampler, population == partitions)
        # reproduces the historic rng.choice draw bit-for-bit.
        from repro.population.scheduler import SamplerContext, make_sampler
        cfg.population.validate()
        self.population_size = int(cfg.population.size or self.n_clients)
        self._part_bucket = np.zeros(self.n_clients, np.int64)
        for p in range(self.n_proto):
            ks = [k for k in range(self.n_clients)
                  if self.client_proto[k] == p]
            if ks:
                self._part_bucket[ks] = assign_buckets(
                    [self.client_steps[k] for k in ks], self.bucket_caps[p])
        # meshless per-(proto, bucket) client caps: the capacity_aware
        # sampler's fill guide (matches _bucket_client_cap without a mesh)
        self._sampler_caps = [
            [min(self.k_cap[p], int(c)) or 1 for c in self._bucket_counts[p]]
            for p in range(self.n_proto)]
        pop_part = np.arange(self.population_size,
                             dtype=np.int64) % self.n_clients
        self.sampler = make_sampler(cfg.population.sampler).bind(
            SamplerContext(
                n_clients=self.population_size,
                n_partitions=self.n_clients,
                proto=np.asarray(self.client_proto, np.int64)[pop_part],
                bucket=self._part_bucket[pop_part],
                bucket_client_caps=self._sampler_caps))
        self._population = None  # built lazily by population()
        cfg.faults.validate()
        self._fault_model = None  # built lazily by fault_model()
        # transfer the eval sets to device ONCE per run: `evaluate`,
        # drop-worst and the distillation val loop otherwise re-upload the
        # same numpy arrays every round (labels stay host-side, they are
        # compared there)
        self.val_x = jnp.asarray(val.x)
        self.test_x = jnp.asarray(test.x)
        # compiled per-prototype batched updates, built lazily so a driver
        # can still attach a mesh (attach_mesh) before first training
        self._updates: Optional[List[Callable]] = None
        if self.mesh is not None:  # ShardingSpec/--shard-clients path
            self._validate_mesh(self.mesh, self.client_axis)

    def _validate_mesh(self, mesh, client_axis: str) -> None:
        """Fail loudly where BOTH mesh paths (constructor-supplied and
        driver-attached) converge, instead of deep inside shard_map.

        Heterogeneous and bucketed runs pad every (prototype, bucket)
        client capacity up to mesh divisibility instead (the padded lanes
        carry all-False step masks and are sliced off), so only the
        historic unbucketed homogeneous path keeps the strict check."""
        if self.heterogeneous or self.cfg.bucketing.kind != "none":
            return
        axis = mesh.shape[client_axis]
        bad = [k for k in self.k_cap if k % axis]
        if bad:
            raise ValueError(
                f"active cohort size(s) {bad} do not divide the "
                f"{client_axis!r} mesh axis ({axis} devices); pick "
                f"client_fraction/n_clients so K is a multiple of the "
                f"device count")

    def _bucket_client_cap(self, p: int, b: int) -> int:
        """Run-fixed client-axis size of (prototype p, bucket b): no round
        can activate more of the bucket's clients than exist, so this
        never retraces; with a mesh it is rounded up to axis divisibility
        (except on the strictly-validated unbucketed homogeneous path)."""
        cap = min(self.k_cap[p], int(self._bucket_counts[p][b])) or 1
        if self.mesh is not None and (self.heterogeneous
                                      or self.cfg.bucketing.kind != "none"):
            axis = self.mesh.shape[self.client_axis]
            cap = -(-cap // axis) * axis
        return cap

    # -- driver-facing setup ----------------------------------------------

    def attach_mesh(self, mesh, client_axis: str = "data") -> None:
        """Shard the client axis of local training over ``mesh`` (multihost
        driver seam).  Must run before the first ``train_clients`` call.
        Heterogeneous / bucketed engines pad their run-fixed per-bucket
        client capacities up to mesh divisibility, so they shard too."""
        if self._updates is not None:
            raise RuntimeError("attach_mesh must be called before the "
                               "first train_clients call")
        self._validate_mesh(mesh, client_axis)
        self.mesh = mesh
        self.client_axis = client_axis

    @property
    def updates(self) -> List[Callable]:
        if self._updates is None:
            prox = self.strategy.local_prox_mu(self.cfg)
            self._updates = [
                make_batched_local_update(
                    self.nets[p], _make_opt(self.cfg), prox_mu=prox,
                    quantize=self.cfg.quantize, dp_clip=self.cfg.dp_clip,
                    dp_noise_multiplier=self.cfg.dp_noise_multiplier,
                    mesh=self.mesh, client_axis=self.client_axis,
                    # the engine rebuilds the batch tensors every round, so
                    # their device buffers are donatable scratch
                    donate_batches=True)
                for p in range(self.n_proto)]
        return self._updates

    def make_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.cfg.seed)

    def init_globals(self) -> List[dict]:
        return [self.nets[p].init(jax.random.PRNGKey(
            self.cfg.seed + p if self.heterogeneous else self.cfg.seed))
            for p in range(self.n_proto)]

    def init_state(self, globals_: List[dict]):
        return self.strategy.init_state(globals_)

    # -- phases -----------------------------------------------------------

    def sample_cohort(self, rng: np.random.Generator) -> np.ndarray:
        """Draw the round's active clients.  The single rng consumer:
        replaying t-1 calls reproduces round t's draw exactly (resume).

        The draw is delegated to the configured cohort sampler
        (population/scheduler.py); the default uniform sampler over a
        population the size of the partition roster IS the historic
        ``rng.choice(n_clients, n_active, replace=False)`` call.  With a
        larger registered population, sampled ids map onto data
        partitions round-robin (several devices share a shard)."""
        with _trace.span("sample_cohort"):
            active = self.sampler.sample(rng, self.n_active)
            if self.population_size != self.n_clients:
                active = np.asarray(active) % self.n_clients
            return active

    def population(self):
        """The lazily-built :class:`PopulationManager` (buffered-async
        driver seam): registry + traffic model + upload buffer sharing
        this engine's bound sampler."""
        if self._population is None:
            from repro.population.manager import PopulationManager
            self._population = PopulationManager(
                self.cfg.population, seed=self.cfg.seed,
                n_partitions=self.n_clients,
                partition_sizes=[len(p) for p in self.parts],
                client_steps=self.client_steps,
                client_proto=self.client_proto,
                client_bucket=self._part_bucket,
                n_active=self.n_active, sampler=self.sampler,
                faults=self.cfg.faults)
        return self._population

    def fault_model(self):
        """The lazily-built counter-based :class:`FaultModel` (None when
        no fault class is enabled — the historic zero-overhead path)."""
        if self._fault_model is None and self.cfg.faults.enabled:
            from repro.population.faults import FaultModel
            self._fault_model = FaultModel(
                self.cfg.faults, self.cfg.seed, self.population_size)
        return self._fault_model

    def fault_pipeline(self, t: int, groups: List[GroupRound],
                       batches: List[Optional[RoundBatches]]):
        """Spanned wrapper around :meth:`_fault_pipeline_body`; the span
        carries the screen/retry/quarantine outcome as attributes and
        the same counts feed the ``core.faults.*`` registry counters."""
        with _trace.span("fault_pipeline", round=int(t)) as sp:
            stats = self._fault_pipeline_body(t, groups, batches)
            if stats is not None:
                sp.annotate(corrupted=stats["corrupted"],
                            quarantined=stats["quarantined"],
                            retries=stats["retries"])
                from repro.obs.metrics import REGISTRY
                REGISTRY.counter("core.faults.corrupted").add(
                    stats["corrupted"])
                REGISTRY.counter("core.faults.quarantined").add(
                    stats["quarantined"])
                REGISTRY.counter("core.faults.retries").add(
                    stats["retries"])
            return stats

    def _fault_pipeline_body(self, t: int, groups: List[GroupRound],
                             batches: List[Optional[RoundBatches]]):
        """Inject, screen and retry on the trained group stacks — the sync
        driver's fault seam (docs/robustness.md).

        Corruption is keyed on ``(seed, wave=t, client, attempt)`` so the
        fault trace never replays across resumes; a retry redraws the
        *transport* faults on the client's clean params (training is
        deterministic), while byzantine clients stay corrupted on every
        attempt and end up quarantined.  Screening (finite-ness + robust-z
        of the delta norm within the cohort) mutates the groups in place,
        dropping quarantined rows.  Returns a stats dict, or None when
        faults are disabled (the stacks are then untouched — bit-identity).
        """
        faults = self.cfg.faults
        fm = self.fault_model()
        if fm is None:
            return None
        from repro.population.faults import (delta_norm, leaves_finite,
                                             outlier_mask, robust_z)
        stats = {"corrupted": 0, "quarantined": 0, "retries": 0,
                 "dispatched": 0, "kept": 0}
        for p, (g, rb) in enumerate(zip(groups, batches)):
            if g.stack is None or rb is None:
                continue
            # the sync/async drivers hand RoundBatches; the distributed
            # driver hands the plain per-proto client-id lists its wire
            # collection assembled (frames carry ids, not batch plans)
            ids = rb.ks if hasattr(rb, "ks") else list(rb)
            flat, treedef = jax.tree.flatten(g.stack)
            host = [np.asarray(l) for l in flat]
            base = [np.asarray(l) for l in jax.tree.leaves(g.prev_global)]
            k = len(ids)
            stats["dispatched"] += k
            clean = [[h[i] for h in host] for i in range(k)]
            rows, touched = [], False
            for i, c in enumerate(ids):
                row, kinds = fm.corrupt(t, c, clean[i], base, attempt=0)
                rows.append(row)
                if kinds:
                    stats["corrupted"] += 1
                    touched = True
            keep = np.ones(k, np.bool_)
            if faults.screen_active:
                # Pass 1 — transport retries: resolve non-finite uploads
                # BEFORE the norm screen, otherwise a burst of NaN drops
                # can gut the cohort and hand the finite median to a
                # byzantine minority (the screen would then bless the
                # attackers and reject the honest survivors).
                next_attempt = np.ones(k, np.int64)
                for i in range(k):
                    while (not leaves_finite(rows[i])
                           and next_attempt[i] <= faults.retries):
                        stats["retries"] += 1
                        row, _ = fm.corrupt(t, ids[i], clean[i], base,
                                            attempt=int(next_attempt[i]))
                        next_attempt[i] += 1
                        if leaves_finite(row):
                            rows[i] = row
                # Pass 2 — adversarial screen over the finite cohort.
                norms = np.array([
                    delta_norm(r, base) if leaves_finite(r) else np.nan
                    for r in rows])
                bad = outlier_mask(norms, faults.norm_sigma)
                ok_norms = norms[~bad]
                med = (float(np.median(ok_norms)) if ok_norms.size else 0.0)
                mad = (float(np.median(np.abs(ok_norms - med)))
                       if ok_norms.size else 0.0)
                for i in np.flatnonzero(bad):
                    accepted = False
                    for attempt in range(int(next_attempt[i]),
                                         faults.retries + 1):
                        stats["retries"] += 1
                        row, _ = fm.corrupt(t, ids[i], clean[i], base,
                                            attempt=attempt)
                        if not leaves_finite(row):
                            continue
                        nrm = delta_norm(row, base)
                        if (ok_norms.size and float(robust_z(
                                np.asarray([nrm]), med, mad)[0])
                                > faults.norm_sigma):
                            continue
                        rows[i] = row
                        accepted = True
                        break
                    if not accepted:
                        keep[i] = False
                        stats["quarantined"] += 1
                        self.sampler.penalize([int(ids[i])], 0.5)
                touched = touched or not keep.all()
            stats["kept"] += int(keep.sum())
            if not touched:
                continue
            kept_i = np.flatnonzero(keep)
            new_host = [
                np.stack([rows[i][li] for i in kept_i], axis=0)
                if kept_i.size else np.zeros((0,) + h.shape[1:], h.dtype)
                for li, h in enumerate(host)]
            if kept_i.size:
                g.stack = jax.tree.unflatten(
                    treedef, [jnp.asarray(h) for h in new_host])
            else:
                g.stack = None
            g.weights = np.asarray(g.weights)[kept_i]
            if g.importance is not None:
                g.importance = np.asarray(g.importance)[kept_i]
        return stats

    def quorum_met(self, stats) -> bool:
        """Did enough uploads survive screening to fuse this round?"""
        import math
        q = self.cfg.faults.quorum
        if q is None or stats is None or stats["dispatched"] == 0:
            return True
        return stats["kept"] >= math.ceil(q * stats["dispatched"] - 1e-9)

    def guard_globals(self, globals_: List[dict], last_good: List[dict]
                      ) -> Tuple[List[dict], List[bool]]:
        """Divergence rollback: any group whose fused globals contain a
        non-finite value is restored to its last-good params.  Gated on
        faults being enabled so historic runs never pay the device
        reduction; returns ``(globals, rolled_back per group)``."""
        rolled = [False] * len(globals_)
        if not self.cfg.faults.enabled:
            return globals_, rolled
        out = []
        for p, (gp, lg) in enumerate(zip(globals_, last_good)):
            if bool(tree_isfinite(gp)):
                out.append(gp)
            else:
                out.append(lg)
                rolled[p] = True
        return out, rolled

    @_spanned("build_round_batches")
    def build_round_batches(
            self, t: int, active: np.ndarray
    ) -> List[Optional[RoundBatches]]:
        """Host-side batch tensors per prototype group — a pure function
        of ``(t, active)``: no rng state, no globals, safe to prefetch."""
        cfg = self.cfg
        by_proto: List[List[int]] = [[] for _ in range(self.n_proto)]
        for k in active:
            by_proto[self.client_proto[k]].append(int(k))
        out: List[Optional[RoundBatches]] = []
        for p in range(self.n_proto):
            ks = by_proto[p]
            if not ks:
                out.append(None)
                continue
            caps = self.bucket_caps[p]
            seeds = [cfg.seed * self.batch_seed_mult + t * 131 + k
                     for k in ks]
            buckets: List[BucketBatch] = []
            real_steps = padded_slots = 0
            for b, pos, xb, yb, step_mask in build_bucketed_batches(
                    self.train.x, self.train.y,
                    [self.parts[k] for k in ks],
                    cfg.local_batch_size, cfg.local_epochs, seeds, caps):
                kb = [ks[i] for i in pos]
                if cfg.dp_clip is not None:
                    dp_keys = np.stack([
                        np.asarray(jax.random.PRNGKey(
                            cfg.seed * 7919 + t * 131 + k)) for k in kb])
                else:
                    dp_keys = np.zeros((len(kb), 2), np.uint32)
                k_real = len(kb)
                cap_k = self._bucket_client_cap(p, b)
                if k_real < cap_k:  # pad the client axis to fixed size
                    pad = cap_k - k_real
                    zpad = lambda a: np.concatenate(
                        [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
                    xb, yb, step_mask, dp_keys = (
                        zpad(xb), zpad(yb), zpad(step_mask), zpad(dp_keys))
                real_steps += int(step_mask.sum())
                padded_slots += cap_k * caps[b]
                buckets.append(BucketBatch(
                    pos=np.asarray(pos), xb=xb, yb=yb, step_mask=step_mask,
                    dp_keys=dp_keys, k_real=k_real, cap_steps=caps[b]))
            weights = np.array([float(len(self.parts[k])) for k in ks])
            out.append(RoundBatches(ks=ks, buckets=buckets, k_real=len(ks),
                                    weights=weights, real_steps=real_steps,
                                    padded_slots=padded_slots))
        return out

    @_spanned("train_clients")
    def train_clients(self, t: int, globals_: List[dict],
                      batches: List[Optional[RoundBatches]]
                      ) -> List[GroupRound]:
        """Run every group's batched local update from ``globals_``.  The
        async driver may pass globals one fusion STALER than sync would
        (bounded staleness; see docs/drivers.md).

        Per-bucket stacks are re-joined IN THE GROUP'S ORIGINAL CLIENT
        ORDER, so aggregation consumes bit-identical inputs whether or
        not bucketing regrouped the vmap axis."""
        groups: List[GroupRound] = []
        for p, rb in enumerate(batches):
            if rb is None:
                groups.append(GroupRound(self.nets[p], globals_[p], None,
                                         np.zeros(0)))
                continue
            pieces = []
            for bb in rb.buckets:
                stack = self.updates[p](globals_[p], jnp.asarray(bb.xb),
                                        jnp.asarray(bb.yb), globals_[p],
                                        jnp.asarray(bb.step_mask),
                                        jnp.asarray(bb.dp_keys))
                if bb.k_real < bb.cap_clients:
                    stack = tree_take(stack, np.arange(bb.k_real))
                pieces.append(stack)
            stack = tree_cat(pieces)
            pos = np.concatenate([bb.pos for bb in rb.buckets])
            if not np.array_equal(pos, np.arange(rb.k_real)):
                inv = np.empty_like(pos)
                inv[pos] = np.arange(len(pos))
                stack = tree_take(stack, inv)
            groups.append(GroupRound(self.nets[p], globals_[p], stack,
                                     rb.weights))
        return groups

    @_spanned("aggregate")
    def aggregate(self, t: int, groups: List[GroupRound], state
                  ) -> Tuple[List[dict], object, List[dict], List[int],
                             Optional[float]]:
        """Drop-worst filter + strategy dispatch.  Returns
        ``(new_globals, new_state, per-group infos, n_dropped per group,
        ensemble_acc)``."""
        cfg = self.cfg
        dropped = [0] * self.n_proto
        if cfg.drop_worst:
            for p, g in enumerate(groups):
                if g.stack is None:
                    continue
                kept, kept_w, kept_i = drop_worst_stacked(
                    g.net, g.stack, g.weights, self.val_x, self.val.y,
                    self.train.n_classes)
                dropped[p] = len(g.weights) - len(kept_i)
                g.stack, g.weights = kept, np.asarray(kept_w)
                if g.importance is not None:
                    g.importance = np.asarray(g.importance)[kept_i]

        ens_acc = None
        if self.heterogeneous:
            from repro.core.ensemble import ensemble_accuracy_stacked
            ens_acc = ensemble_accuracy_stacked(
                [(g.net, g.stack) for g in groups if g.stack is not None],
                self.test_x, self.test.y)

        ctx = RoundContext(cfg=cfg, round=t,
                           heterogeneous=self.heterogeneous,
                           source=self.source, val_x=self.val_x,
                           val_y=self.val.y, test_x=self.test_x,
                           test_y=self.test.y)
        globals_, state, infos = self.strategy.aggregate(groups, state, ctx)
        return globals_, state, infos, dropped, ens_acc

    @_spanned("evaluate_round")
    def evaluate_round(self, t: int, globals_: List[dict],
                       groups: List[GroupRound], infos: List[dict],
                       dropped: List[int], ens_acc: Optional[float]
                       ) -> List[RoundLog]:
        cfg = self.cfg
        out = []
        for p in range(self.n_proto):
            acc = evaluate(self.nets[p], globals_[p], self.test_x,
                           self.test.y, quantize=cfg.quantize)
            vacc = evaluate(self.nets[p], globals_[p], self.val_x,
                            self.val.y, quantize=cfg.quantize)
            out.append(RoundLog(
                round=t, test_acc=acc, val_acc=vacc, ensemble_acc=ens_acc,
                pre_distill_acc=infos[p].get("pre_distill_acc"),
                distill_steps=infos[p].get("distill_steps", 0),
                n_participants=len(groups[p].weights),
                n_dropped=dropped[p],
                teacher_forwards=infos[p].get("teacher_forwards", 0),
                bank=infos[p].get("bank", ""),
                bank_dtype=infos[p].get("bank_dtype", ""),
                bank_nbytes=infos[p].get("bank_nbytes", 0),
                n_teachers_filtered=infos[p].get("teachers_filtered", 0),
                rolled_back=bool(infos[p].get("diverged", False))))
        return out

    def target_reached(self, round_logs: List[RoundLog]) -> bool:
        """Rounds-to-target early-stop criterion.  Homogeneous: the global
        model's test accuracy.  Heterogeneous: the best prototype's test
        accuracy this round (every client owns one of the prototypes, so
        the fleet has reached the target when its best group has)."""
        if self.cfg.target_accuracy is None:
            return False
        return max(l.test_acc for l in round_logs) >= self.cfg.target_accuracy


def run_rounds(
    nets: List[Net],
    client_proto: Sequence[int],          # client k -> prototype index
    train: Dataset,
    parts: Sequence[np.ndarray],
    val: Dataset,
    test: Dataset,
    cfg: FLConfig,
    *,
    source: Optional[DistillSource] = None,
    log_fn: Optional[Callable] = None,
    heterogeneous: bool = False,
    mesh=None,
    client_axis: str = "data",
    init_globals: Optional[List[dict]] = None,
    init_state=_UNSET,
    start_round: int = 1,
    init_logs: Optional[List[List["RoundLog"]]] = None,
    round_end_hook: Optional[Callable] = None,
    driver=None,
) -> Tuple[List[FLResult], List[dict], Optional[int]]:
    """The shared round loop.  Returns (per-prototype results, final
    globals, rounds_to_target).  ``mesh`` shards the client axis of local
    training over ``client_axis``; heterogeneous / bucketed runs pad
    their run-fixed per-bucket client capacities up to mesh divisibility,
    the unbucketed homogeneous path requires the active cohort size to
    divide the axis size (validated loudly).  Homogeneous
    callers pass one net and ``client_proto`` all zeros; ``log_fn``
    receives ``RoundLog`` (homogeneous) or ``(group, RoundLog)``
    (heterogeneous) to match the historic APIs, and may return a truthy
    value to request a stop after the current round (the
    ``RoundEvent.request_stop`` seam).

    ``driver`` selects the round driver (``repro.drivers`` registry): a
    name, a :class:`repro.drivers.Driver` instance, or None for the
    default ``sync`` driver — the historic serial loop, bit-identical.

    Resume support (``repro.api.Experiment.resume``): pass the
    checkpointed ``init_globals`` / ``init_state`` / ``init_logs`` and
    ``start_round = <last completed round> + 1``; the cohort-sampling rng
    replays the completed rounds' draws so the trajectory is identical to
    an uninterrupted run.  ``round_end_hook(t, globals_, state, logs,
    rounds_to_target)`` fires after every completed round in round order
    (this is the checkpoint seam) for every driver."""
    from repro.drivers import resolve_driver

    engine = RoundEngine(nets, client_proto, train, parts, val, test, cfg,
                         source=source, heterogeneous=heterogeneous,
                         mesh=mesh, client_axis=client_axis)
    drv = resolve_driver(driver)
    return drv.run(engine, log_fn=log_fn, init_globals=init_globals,
                   init_state=init_state, start_round=start_round,
                   init_logs=init_logs, round_end_hook=round_end_hook)

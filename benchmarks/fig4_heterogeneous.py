"""Figure 4: heterogeneous systems — three distinct prototypes
(ResNet-20/32/ShuffleNetV2 analogue: different widths/depths).  FedDF
dominates per-group FedAvg each round, with the ensemble as upper bound."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated_heterogeneous


def run(seed: int = 0) -> dict:
    rounds = scale(4, 10)
    t0 = time.time()
    train, val, test, parts, src = default_problem(seed=seed, alpha=1.0,
                                                   n_clients=9)
    nets = [mlp(2, 3, hidden=(32, 32), name="proto-s"),
            mlp(2, 3, hidden=(64, 64), name="proto-m"),
            mlp(2, 3, hidden=(48, 48, 48), name="proto-d")]
    proto = [k % 3 for k in range(9)]
    results = {}
    for strat, source in (("fedavg", None), ("feddf", src)):
        cfg = fl_cfg(strat, rounds, seed=seed, client_fraction=0.67)
        res, _ = run_federated_heterogeneous(nets, proto, train, parts, val,
                                             test, cfg, source=source)
        for g, r in enumerate(res):
            results[f"{strat}/proto{g}"] = {
                "per_round": [l.test_acc for l in r.logs],
                "best": r.best_acc,
                "ensemble": [l.ensemble_acc for l in r.logs]}
    dt = time.time() - t0
    feddf_mean = np.mean([results[f"feddf/proto{g}"]["best"]
                          for g in range(3)])
    fedavg_mean = np.mean([results[f"fedavg/proto{g}"]["best"]
                           for g in range(3)])
    ens = max(results["feddf/proto0"]["ensemble"])
    claims = {
        "feddf_dominates_groupwise_fedavg": feddf_mean >= fedavg_mean - 0.01,
        "ensemble_is_upper_bound":
            ens >= max(results[f"feddf/proto{g}"]["best"]
                       for g in range(3)) - 0.03,
    }
    emit("fig4_heterogeneous", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims,
          "feddf_mean": float(feddf_mean), "fedavg_mean": float(fedavg_mean)})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

"""Deterministic fault injection + upload screening for the population.

:class:`FaultModel` mirrors :class:`~repro.population.traffic.TrafficModel`:
every draw is keyed on ``(salt, seed, domain, wave, client, attempt)``
through ``np.random.default_rng``'s SeedSequence, so the fault trace is a
pure function of (config, seed) — resuming a run never replays or shifts
which uploads are corrupted, and a retry (``attempt`` bump) redraws the
transport faults without touching any sequential RNG state.

Fault taxonomy (docs/robustness.md):

- **byzantine** — a persistent (static-domain) subset of clients whose
  upload delta is adversarially transformed every round: ``sign_flip``
  sends ``base - scale * delta``, ``scale`` sends ``base + scale * delta``.
- **crash** — the client dies mid-upload: all parameters after a random
  cut point in the flattened payload arrive as zeros (a torn, partial
  upload).
- **bitflip** — transport corruption of the serialized payload: a few
  random bits of one float32 tensor are XOR'd (float32 viewed as uint32).
- **nan** — one tensor entry is replaced by NaN/+Inf/-Inf.

Corruption operates on host-side numpy leaf lists (the one-row pytrees the
drivers move around), never inside jit.

:class:`NormScreen` is the matching defense: finite-ness checks plus
robust-z (median / MAD) outlier screening of upload delta norms, either
within one cohort (sync driver) or against a rolling per-prototype window
of accepted norms (buffered_async).  Its rolling state checkpoints through
``state_dict`` so resumed runs screen identically.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.population.config import FaultConfig

_SALT = 0xFA_17BAD
_DOMAINS = {"static": 0, "corrupt": 1, "transport": 2}

# MAD floor as a fraction of the median: when honest norms are (near)
# identical the MAD collapses to 0 and any jitter would z-score to
# infinity; requiring > sigma * 5% relative deviation keeps honest
# uploads safe while scale-10 byzantine deltas still score in the 100s.
_REL_MAD_FLOOR = 0.05
_MAD_TO_SIGMA = 1.4826


class FaultModel:
    """Counter-based corruption draws for ``n`` registered clients."""

    def __init__(self, cfg: FaultConfig, seed: int, n: int):
        cfg.validate()
        self.cfg = cfg
        self.seed = int(seed)
        self.n = int(n)
        rng = self._rng("static", 0, 0, 0)
        self.byzantine = (rng.random(self.n) < cfg.byzantine_frac
                          if cfg.byzantine_frac > 0
                          else np.zeros(self.n, np.bool_))

    def _rng(self, domain: str, wave: int, client: int,
             attempt: int) -> np.random.Generator:
        return np.random.default_rng(
            (_SALT, self.seed, _DOMAINS[domain], int(wave), int(client),
             int(attempt)))

    # -- injection -------------------------------------------------------

    def corrupt(self, wave: int, client: int, leaves: Sequence[np.ndarray],
                base_leaves: Sequence[np.ndarray],
                attempt: int = 0) -> Tuple[List[np.ndarray], Tuple[str, ...]]:
        """Apply this upload's faults; returns ``(new_leaves, kinds)``.

        ``leaves`` / ``base_leaves`` are matching flat leaf lists of the
        uploaded params and the global model they trained from.  Input
        arrays are never mutated; untouched leaves are passed through by
        reference.  ``kinds`` names the fault classes that fired (empty
        for a clean upload).
        """
        cfg = self.cfg
        out: List[np.ndarray] = [np.asarray(l) for l in leaves]
        kinds: List[str] = []
        if self.byzantine[int(client)]:
            scale = cfg.byzantine_scale
            for i, (l, b) in enumerate(zip(out, base_leaves)):
                if not np.issubdtype(l.dtype, np.floating):
                    continue
                b = np.asarray(b, l.dtype)
                delta = l.astype(np.float64) - b.astype(np.float64)
                if cfg.byzantine_mode == "sign_flip":
                    new = b.astype(np.float64) - scale * delta
                else:
                    new = b.astype(np.float64) + scale * delta
                out[i] = new.astype(l.dtype)
            kinds.append("byzantine")
        rng = self._rng("corrupt", wave, client, attempt)
        # one unconditional uniform per fault class keeps the draw layout
        # (and thus every downstream draw) stable as rates are tuned
        u = rng.random(3)
        if cfg.crash_rate > 0 and u[0] < cfg.crash_rate:
            self._crash(rng, out)
            kinds.append("crash")
        if cfg.bitflip_rate > 0 and u[1] < cfg.bitflip_rate:
            if self._bitflip(rng, out):
                kinds.append("bitflip")
        if cfg.nan_rate > 0 and u[2] < cfg.nan_rate:
            if self._poison(rng, out):
                kinds.append("nan")
        return out, tuple(kinds)

    @staticmethod
    def _crash(rng: np.random.Generator, out: List[np.ndarray]) -> None:
        sizes = [int(l.size) for l in out]
        total = sum(sizes)
        if total < 2:
            return
        cut = int(rng.integers(1, total))  # at least one param survives
        seen = 0
        for i, l in enumerate(out):
            if seen >= cut:
                out[i] = np.zeros_like(l)
            elif seen + sizes[i] > cut:
                flat = np.array(l).reshape(-1)
                flat[cut - seen:] = 0
                out[i] = flat.reshape(l.shape)
            seen += sizes[i]

    def _bitflip(self, rng: np.random.Generator,
                 out: List[np.ndarray]) -> bool:
        cand = [i for i, l in enumerate(out)
                if l.dtype == np.float32 and l.size > 0]
        if not cand:
            return False
        i = int(cand[int(rng.integers(len(cand)))])
        flat = np.array(out[i]).reshape(-1)
        nb = self.cfg.bitflip_bits
        idx = rng.integers(0, flat.size, size=nb)
        bits = rng.integers(0, 32, size=nb).astype(np.uint32)
        view = flat.view(np.uint32)
        view[idx] ^= np.uint32(1) << bits
        out[i] = flat.reshape(out[i].shape)
        return True

    # -- transport domain (distributed runtime, docs/distributed.md) ----

    def transport_fault(self, wave: int, pod: int,
                        attempt: int) -> Optional[str]:
        """Fault class for one UPLOAD frame, keyed ``(round, pod, attempt)``.

        Returns ``"disconnect"`` / ``"drop"`` / ``"corrupt"`` / ``"delay"``
        or None.  One unconditional uniform per class keeps the draw
        layout stable as rates are tuned; an ``attempt`` bump (PR 8 retry
        bookkeeping) is a fresh draw, never a replay.  At most one class
        fires per frame, checked in severity order.
        """
        cfg = self.cfg
        rng = self._rng("transport", wave, pod, attempt)
        u = rng.random(4)
        if cfg.transport_disconnect > 0 and u[0] < cfg.transport_disconnect:
            return "disconnect"
        if cfg.transport_drop > 0 and u[1] < cfg.transport_drop:
            return "drop"
        if cfg.transport_corrupt > 0 and u[2] < cfg.transport_corrupt:
            return "corrupt"
        if cfg.transport_delay > 0 and u[3] < cfg.transport_delay:
            return "delay"
        return None

    def corrupt_frame(self, wave: int, pod: int, attempt: int,
                      data: bytes, n_bytes: int = 4) -> bytes:
        """Deterministically flip ``n_bytes`` bytes of an encoded frame.

        Re-derives the same generator as :meth:`transport_fault` (skipping
        its four class uniforms) so the corruption positions are a pure
        function of (config, seed, round, pod, attempt).
        """
        rng = self._rng("transport", wave, pod, attempt)
        rng.random(4)  # skip the class draws
        buf = bytearray(data)
        if not buf:
            return bytes(buf)
        idx = rng.integers(0, len(buf), size=n_bytes)
        masks = rng.integers(1, 256, size=n_bytes)
        for i, m in zip(idx, masks):
            buf[int(i)] ^= int(m)
        return bytes(buf)

    @staticmethod
    def _poison(rng: np.random.Generator, out: List[np.ndarray]) -> bool:
        cand = [i for i, l in enumerate(out)
                if np.issubdtype(l.dtype, np.floating) and l.size > 0]
        if not cand:
            return False
        i = int(cand[int(rng.integers(len(cand)))])
        flat = np.array(out[i]).reshape(-1)
        j = int(rng.integers(flat.size))
        flat[j] = (np.nan, np.inf, -np.inf)[int(rng.integers(3))]
        out[i] = flat.reshape(out[i].shape)
        return True


def _float_leaves(leaves: Sequence[np.ndarray]) -> List[np.ndarray]:
    return [np.asarray(l) for l in leaves
            if np.issubdtype(np.asarray(l).dtype, np.floating)]


def leaves_finite(leaves: Sequence[np.ndarray]) -> bool:
    """True iff every float leaf is entirely finite (host-side)."""
    return all(bool(np.isfinite(l).all()) for l in _float_leaves(leaves))


def delta_norm(leaves: Sequence[np.ndarray],
               base_leaves: Sequence[np.ndarray]) -> float:
    """Global L2 norm of the upload delta across float leaves."""
    total = 0.0
    for l, b in zip(leaves, base_leaves):
        l = np.asarray(l)
        if not np.issubdtype(l.dtype, np.floating):
            continue
        d = l.astype(np.float64) - np.asarray(b, np.float64)
        total += float(np.sum(d * d))
    return math.sqrt(total)


def robust_z(values: np.ndarray, center: float, mad: float) -> np.ndarray:
    """|z| against a median/MAD location estimate, with a relative floor."""
    denom = _MAD_TO_SIGMA * mad + _REL_MAD_FLOOR * abs(center) + 1e-12
    return np.abs(np.asarray(values, np.float64) - center) / denom


def outlier_mask(norms: Sequence[float], sigma: float) -> np.ndarray:
    """Within-cohort screen: True where a norm is a robust-z outlier.

    Non-finite norms are always outliers; the median/MAD baseline is
    computed over the finite subset only.
    """
    norms = np.asarray(norms, np.float64)
    bad = ~np.isfinite(norms)
    finite = norms[~bad]
    if finite.size == 0:
        return np.ones_like(bad)
    med = float(np.median(finite))
    mad = float(np.median(np.abs(finite - med)))
    z = robust_z(norms, med, mad)
    return bad | (z > sigma)


class NormScreen:
    """Rolling per-prototype delta-norm screen for the buffered path.

    Keeps a bounded window of recently *accepted* norms per prototype;
    an incoming upload is rejected when its norm robust-z-scores beyond
    ``sigma`` against that window.  The first ``min_history`` uploads per
    prototype are screened for finiteness only (no baseline yet).
    """

    def __init__(self, sigma: float = 6.0, window: int = 128,
                 min_history: int = 4):
        self.sigma = float(sigma)
        self.window = int(window)
        self.min_history = int(min_history)
        self.history: Dict[int, List[float]] = {}

    def check(self, proto: int, norm: float) -> Tuple[bool, Optional[str]]:
        """Screen one upload; accepted norms enter the window."""
        if not math.isfinite(norm):
            return False, "nonfinite"
        hist = self.history.setdefault(int(proto), [])
        if len(hist) >= self.min_history:
            arr = np.asarray(hist, np.float64)
            med = float(np.median(arr))
            mad = float(np.median(np.abs(arr - med)))
            if float(robust_z(np.asarray([norm]), med, mad)[0]) > self.sigma:
                return False, "norm_outlier"
        hist.append(float(norm))
        if len(hist) > self.window:
            del hist[:len(hist) - self.window]
        return True, None

    # -- checkpointing ---------------------------------------------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        d: Dict[str, np.ndarray] = {
            "protos": np.asarray(sorted(self.history), np.int64)}
        for p in sorted(self.history):
            d[f"hist_{p}"] = np.asarray(self.history[p], np.float64)
        return d

    def load_state(self, d: Dict[str, np.ndarray]) -> None:
        self.history = {}
        for p in np.asarray(d["protos"], np.int64).tolist():
            self.history[int(p)] = [
                float(x) for x in np.asarray(d[f"hist_{p}"], np.float64)]

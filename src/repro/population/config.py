"""Engine-level population / traffic configuration (dependency-free).

These mirror the spec-layer :class:`repro.api.spec.PopulationSpec` /
:class:`TrafficSpec` the way ``FLConfig`` mirrors ``ExperimentSpec``:
plain dataclasses the engine and drivers consume, with no knowledge of
JSON round-tripping.  ``docs/population.md`` documents the knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.common.options import ARRIVAL_KINDS, BYZANTINE_MODES, SCREEN_MODES


@dataclasses.dataclass
class TrafficConfig:
    """Arrival / latency / dropout model for the client population.

    All draws are counter-based (keyed on ``(seed, domain, wave)``), so a
    trace is a pure function of the config + seed: resuming a run never
    replays or shifts the schedule.
    """
    arrival: str = "always"       # always | bernoulli (per-wave online draw)
    rate: float = 1.0             # P(online) per wave under bernoulli
    latency: float = 0.0          # mean upload latency, virtual seconds
    jitter: float = 0.0           # lognormal sigma: per-client speed AND
    #                               per-upload latency noise
    straggler_frac: float = 0.0   # fraction of persistently slow clients
    straggler_mult: float = 8.0   # their latency multiplier
    dropout: float = 0.0          # P(upload lost) per dispatch

    def validate(self) -> None:
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"options: {ARRIVAL_KINDS}")
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"traffic rate must be in (0, 1], got {self.rate}")
        if self.latency < 0 or self.jitter < 0:
            raise ValueError("latency and jitter must be >= 0")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(f"straggler_frac must be in [0, 1], "
                             f"got {self.straggler_frac}")
        if self.straggler_mult < 1.0:
            raise ValueError(f"straggler_mult must be >= 1, "
                             f"got {self.straggler_mult}")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")


@dataclasses.dataclass
class FaultConfig:
    """Fault injection + defense knobs (mirrors spec-layer ``FaultSpec``).

    Injection rates are per-upload probabilities drawn counter-based by
    :class:`repro.population.faults.FaultModel`; byzantine clients are a
    persistent (static-domain) subset like traffic stragglers.  Defenses
    default to ``"auto"``: active iff any injection rate is positive, so
    fault-free configs stay bit-identical to historic trajectories.
    """
    nan_rate: float = 0.0         # P(one tensor entry -> NaN/Inf) per upload
    byzantine_frac: float = 0.0   # fraction of persistently adversarial
    #                               clients (static draw, like stragglers)
    byzantine_scale: float = 10.0  # delta amplification for byzantine rows
    byzantine_mode: str = "sign_flip"  # sign_flip | scale
    bitflip_rate: float = 0.0     # P(payload bit corruption) per upload
    bitflip_bits: int = 4         # XOR'd bits per corrupted payload
    crash_rate: float = 0.0      # P(client crashes mid-round) per upload:
    #                               trailing leaves of the delta are zeroed
    screen: str = "auto"          # auto | on | off: finite + norm screening
    norm_sigma: float = 6.0       # robust-z threshold for delta-norm outliers
    teacher_filter: str = "auto"  # auto | on | off: FedDF consensus filter
    teacher_sigma: float = 6.0    # robust-z threshold on logit divergence
    quorum: Optional[float] = None  # min usable-upload fraction to fuse;
    #                                 None keeps historic strictness
    retries: int = 2              # re-dispatch attempts for rejected uploads
    backoff: float = 2.0          # exponential backoff base, virtual seconds
    # transport fault domain (distributed runtime, docs/distributed.md):
    # per-UPLOAD-frame probabilities drawn counter-based per
    # (round, pod, attempt) — a retry is a fresh draw, never a replay
    transport_drop: float = 0.0        # frame silently discarded
    transport_corrupt: float = 0.0     # frame bytes flipped (CRC catches)
    transport_delay: float = 0.0       # frame held transport_delay_s
    transport_delay_s: float = 0.25    # hold duration, wall seconds
    transport_disconnect: float = 0.0  # pod goes dark for the round

    @property
    def enabled(self) -> bool:
        """True iff any *parameter* fault class can actually fire.

        Deliberately excludes the transport domain: frame-level faults
        are defended at the wire layer (CRC / deadline / quorum), and
        arming the statistical screens for them would perturb fault-free
        parameter paths.
        """
        return (self.nan_rate > 0 or self.byzantine_frac > 0
                or self.bitflip_rate > 0 or self.crash_rate > 0)

    @property
    def transport_enabled(self) -> bool:
        """True iff any transport (frame-level) fault class can fire."""
        return (self.transport_drop > 0 or self.transport_corrupt > 0
                or self.transport_delay > 0 or self.transport_disconnect > 0)

    @property
    def screen_active(self) -> bool:
        return self.screen == "on" or (self.screen == "auto" and self.enabled)

    @property
    def teacher_filter_active(self) -> bool:
        return (self.teacher_filter == "on"
                or (self.teacher_filter == "auto" and self.enabled))

    def validate(self) -> None:
        for name in ("nan_rate", "bitflip_rate", "crash_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if not 0.0 <= self.byzantine_frac <= 1.0:
            raise ValueError(f"byzantine_frac must be in [0, 1], "
                             f"got {self.byzantine_frac}")
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(f"unknown byzantine_mode "
                             f"{self.byzantine_mode!r}; "
                             f"options: {BYZANTINE_MODES}")
        if self.byzantine_scale <= 0:
            raise ValueError(f"byzantine_scale must be > 0, "
                             f"got {self.byzantine_scale}")
        if self.bitflip_bits < 1:
            raise ValueError(f"bitflip_bits must be >= 1, "
                             f"got {self.bitflip_bits}")
        for name in ("screen", "teacher_filter"):
            v = getattr(self, name)
            if v not in SCREEN_MODES:
                raise ValueError(f"unknown {name} mode {v!r}; "
                                 f"options: {SCREEN_MODES}")
        if self.norm_sigma <= 0 or self.teacher_sigma <= 0:
            raise ValueError("norm_sigma and teacher_sigma must be > 0")
        if self.quorum is not None and not 0.0 < self.quorum <= 1.0:
            raise ValueError(f"quorum must be in (0, 1], got {self.quorum}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        for name in ("transport_drop", "transport_corrupt",
                     "transport_delay", "transport_disconnect"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.transport_delay_s < 0:
            raise ValueError(f"transport_delay_s must be >= 0, "
                             f"got {self.transport_delay_s}")


@dataclasses.dataclass
class PopulationConfig:
    """Population size, cohort sampling policy and upload-buffer shape."""
    size: Optional[int] = None         # registered clients; None -> one per
    #                                    data partition (the classic roster)
    sampler: str = "uniform"           # population/scheduler.py registry
    buffer_size: Optional[int] = None  # M uploads per aggregation; None -> K
    max_staleness: int = 4             # uploads older than S rounds dropped
    staleness_exponent: float = 0.5    # a in the (1 + s)^-a FedAsync weight
    traffic: TrafficConfig = dataclasses.field(default_factory=TrafficConfig)

    def validate(self) -> None:
        if self.size is not None and self.size < 1:
            raise ValueError(f"population size must be >= 1, got {self.size}")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, "
                             f"got {self.buffer_size}")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, "
                             f"got {self.max_staleness}")
        if self.staleness_exponent < 0:
            raise ValueError(f"staleness_exponent must be >= 0, "
                             f"got {self.staleness_exponent}")
        self.traffic.validate()

"""Extensions: client-level DP uploads (paper §3) and SWAG teachers (Tab. 7)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FLConfig, FusionConfig, mlp, run_federated
from repro.core.privacy import (clip_by_global_norm, global_norm,
                                privatize_update)
from repro.core.swag import swag_fit, swag_sample, swag_teachers
from repro.data import UnlabeledDataset, dirichlet_partition, \
    gaussian_mixture, train_val_test_split


def _params(seed=0, scale=1.0):
    net = mlp(2, 3, hidden=(8,))
    p = net.init(jax.random.PRNGKey(seed))
    return net, jax.tree.map(lambda x: x * scale, p)


# ---------------------------------------------------------------------------
# privacy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("clip", [0.1, 1.0, 10.0])
def test_clip_bounds_global_norm(clip):
    _, p = _params(scale=5.0)
    clipped = clip_by_global_norm(p, clip)
    assert float(global_norm(clipped)) <= clip * (1 + 1e-5)


def test_clip_noop_below_threshold():
    _, p = _params(scale=1e-3)
    clipped = clip_by_global_norm(p, 100.0)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(clipped)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_privatize_is_deterministic_and_noise_scales():
    _, g = _params(0)
    _, c = _params(1, scale=2.0)
    key = jax.random.PRNGKey(42)
    p1 = privatize_update(g, c, clip=1.0, noise_multiplier=0.5, key=key)
    p2 = privatize_update(g, c, clip=1.0, noise_multiplier=0.5, key=key)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)
    # zero noise == pure clipping; delta norm bounded by clip
    p0 = privatize_update(g, c, clip=1.0, noise_multiplier=0.0, key=key)
    delta = jax.tree.map(lambda a, b: a - b, p0, g)
    assert float(global_norm(delta)) <= 1.0 + 1e-5


def test_dp_federated_run_trains():
    ds = gaussian_mixture(800, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds, seed=0)
    parts = dirichlet_partition(train.y, 4, 1.0, seed=0)
    cfg = FLConfig(rounds=2, client_fraction=1.0, local_epochs=3,
                   local_batch_size=32, local_lr=0.05, strategy="fedavg",
                   dp_clip=5.0, dp_noise_multiplier=0.01, seed=0,
                   fusion=FusionConfig(max_steps=50, patience=50,
                                       eval_every=25, batch_size=32))
    net = mlp(2, 3, hidden=(16, 16))
    res = run_federated(net, train, parts, val, test, cfg)
    assert res.final_acc > 0.4  # still learns under mild DP


# ---------------------------------------------------------------------------
# SWAG teachers
# ---------------------------------------------------------------------------

def test_swag_fit_and_sample_shapes():
    clients = [_params(i)[1] for i in range(4)]
    mean, var = swag_fit(clients)
    for m, v, c in zip(jax.tree.leaves(mean), jax.tree.leaves(var),
                       jax.tree.leaves(clients[0])):
        assert m.shape == c.shape == v.shape
        assert float(jnp.min(v)) >= 0.0
    teachers = swag_teachers(clients, 3, seed=0)
    assert len(teachers) == 7  # 4 received + 3 sampled


def test_swag_zero_scale_samples_equal_mean():
    clients = [_params(i)[1] for i in range(3)]
    mean, var = swag_fit(clients)
    (s,) = swag_sample(mean, var, 1, scale=0.0, seed=1)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(mean)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_feddf_with_swag_and_sgd_fusion_runs():
    ds = gaussian_mixture(800, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds, seed=0)
    parts = dirichlet_partition(train.y, 4, 1.0, seed=0)
    src = UnlabeledDataset(np.random.default_rng(7).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    for fkw in (dict(optimizer="sgd", lr=0.05),
                dict(swag_samples=2, swag_scale=0.25)):
        cfg = FLConfig(rounds=1, client_fraction=1.0, local_epochs=3,
                       local_batch_size=32, local_lr=0.05, strategy="feddf",
                       seed=0,
                       fusion=FusionConfig(max_steps=50, patience=50,
                                           eval_every=25, batch_size=32,
                                           **fkw))
        net = mlp(2, 3, hidden=(16, 16))
        res = run_federated(net, train, parts, val, test, cfg, source=src)
        assert res.final_acc > 0.4

"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only (bidirectional), conv feature-extractor frontend is a stub
delivering frame embeddings. [arXiv:2106.07447]"""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,   # HuBERT cluster-unit targets
    head_dim=80,
    causal=False,     # encoder-only
    frontend="audio_frames",
    pattern=(BlockSpec("attn_global", "gelu"),),
)

"""Figure 2 (bottom): FedDF's margin over FedAvg GROWS with more local
epochs (ensemble diversity ↑), while FedAvg saturates/degrades."""
from __future__ import annotations

import time

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated


def run(seed: int = 0) -> dict:
    rounds = scale(5, 12)
    t0 = time.time()
    train, val, test, parts, src = default_problem(seed=seed, alpha=0.3)
    net = mlp(2, 3, hidden=(48, 48))
    results = {}
    for epochs in (1, 20, 40):
        for strat, source in (("fedavg", None), ("feddf", src)):
            cfg = fl_cfg(strat, rounds, seed=seed, local_epochs=epochs)
            res = run_federated(net, train, parts, val, test, cfg,
                                source=source)
            results[f"E={epochs}/{strat}"] = res.best_acc
    dt = time.time() - t0
    margin_1 = results["E=1/feddf"] - results["E=1/fedavg"]
    margin_40 = results["E=40/feddf"] - results["E=40/fedavg"]
    claims = {
        # with sufficient local training FedDF holds a margin over FedAvg
        "feddf_wins_at_40_epochs":
            results["E=40/feddf"] >= results["E=40/fedavg"] - 0.005,
        "margin_grows_with_epochs": margin_40 >= margin_1 - 0.03,
    }
    emit("fig2_local_epochs", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims,
          "margin_E1": margin_1, "margin_E40": margin_40})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

from repro.kernels import ref
from repro.kernels.ops import ensemble_kl_loss, ssd_scan, swa_attention

"""Versioned, checksummed wire format of the distributed runtime.

A frame is ``(version, kind, codec_id, round, wave, client_ids, meta,
payload)`` + a trailing CRC32 over everything after the magic, so any
bit-flip in transit is detected before the payload is trusted.  The
codec registry here is the transport face of the quantizer registry:
``binarize`` applies the same sign * mean|w| transform as the
``binarize`` quantizer (``core/quantize.py``) and its bytes-on-wire
match ``quantize.comm_bytes(params, binarized=True)`` exactly; ``int8``
is the low-bit absmax codec mirroring the quantized logit-bank storage
(``LogitBank.nbytes``-style size + one fp32 scale per leaf).

stdlib + numpy only — importable by the jax-free spec layer.
"""
from __future__ import annotations

import json
import struct
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

MAGIC = b"RW"
WIRE_VERSION = 1

# frame kinds
HELLO = 0  # pod -> fusion: {"pod": j} introduction (tcp connection mapping)
TRAIN = 1  # fusion -> pod: round globals + the client ids to train
UPLOAD = 2  # pod -> fusion: trained client deltas, one blob per client id
HEARTBEAT = 3  # pod -> fusion: liveness beacon, every heartbeat_s
SHUTDOWN = 4  # fusion -> pod: drain and exit

KIND_NAMES = {HELLO: "hello", TRAIN: "train", UPLOAD: "upload",
              HEARTBEAT: "heartbeat", SHUTDOWN: "shutdown"}

_HEADER = struct.Struct("<HBBII")  # version, kind, codec_id, round, wave
_U32 = struct.Struct("<I")
_F32 = struct.Struct("<f")


class FrameError(Exception):
    """Malformed frame (bad magic, truncation, garbage lengths)."""


class CRCError(FrameError):
    """Checksum mismatch — payload corrupted in transit."""


class VersionError(FrameError):
    """Peer speaks a different wire version."""


@dataclass
class Frame:
    kind: int
    round: int = 0
    wave: int = 0
    client_ids: Sequence[int] = ()
    codec_id: int = 0
    meta: Dict = field(default_factory=dict)
    payload: bytes = b""
    version: int = WIRE_VERSION


def encode_frame(frame: Frame) -> bytes:
    ids = np.asarray(list(frame.client_ids), dtype=np.int64)
    meta = json.dumps(frame.meta, sort_keys=True).encode("utf-8")
    body = b"".join(
        [
            _HEADER.pack(frame.version, frame.kind, frame.codec_id,
                         frame.round, frame.wave),
            _U32.pack(ids.size),
            ids.tobytes(),
            _U32.pack(len(meta)),
            meta,
            _U32.pack(len(frame.payload)),
            frame.payload,
        ]
    )
    return MAGIC + body + _U32.pack(zlib.crc32(body) & 0xFFFFFFFF)


def decode_frame(data: bytes, *, verify_crc: bool = True) -> Frame:
    if len(data) < len(MAGIC) + _HEADER.size + 3 * _U32.size + _U32.size:
        raise FrameError(f"frame too short ({len(data)} bytes)")
    if data[: len(MAGIC)] != MAGIC:
        raise FrameError("bad magic")
    body, crc_bytes = data[len(MAGIC):-_U32.size], data[-_U32.size:]
    version, kind, codec_id, rnd, wave = _HEADER.unpack_from(body, 0)
    # version precedes CRC: a peer on another protocol revision is
    # reported as such, not as line noise
    if version != WIRE_VERSION:
        raise VersionError(f"wire version {version} != {WIRE_VERSION}")
    if verify_crc and _U32.unpack(crc_bytes)[0] != (zlib.crc32(body) & 0xFFFFFFFF):
        raise CRCError("frame CRC mismatch")
    off = _HEADER.size
    (n_ids,) = _U32.unpack_from(body, off)
    off += _U32.size
    if off + 8 * n_ids > len(body):
        raise FrameError("truncated client_ids")
    ids = np.frombuffer(body, dtype=np.int64, count=n_ids, offset=off)
    off += 8 * n_ids
    (meta_len,) = _U32.unpack_from(body, off)
    off += _U32.size
    if off + meta_len > len(body):
        raise FrameError("truncated meta")
    try:
        meta = json.loads(body[off: off + meta_len].decode("utf-8")) if meta_len else {}
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FrameError(f"undecodable meta: {e}")
    off += meta_len
    (payload_len,) = _U32.unpack_from(body, off)
    off += _U32.size
    if off + payload_len > len(body):
        raise FrameError("truncated payload")
    payload = bytes(body[off: off + payload_len])
    return Frame(kind=kind, round=rnd, wave=wave, client_ids=[int(i) for i in ids],
                 codec_id=codec_id, meta=meta, payload=payload, version=version)


# ---------------------------------------------------------------------------
# blob packing: an UPLOAD payload is one length-prefixed blob per client id


def pack_blobs(blobs: Sequence[bytes]) -> bytes:
    return b"".join(_U32.pack(len(b)) + b for b in blobs)


def unpack_blobs(data: bytes, n: int) -> List[bytes]:
    out, off = [], 0
    for _ in range(n):
        if off + _U32.size > len(data):
            raise FrameError("truncated blob stream")
        (ln,) = _U32.unpack_from(data, off)
        off += _U32.size
        if off + ln > len(data):
            raise FrameError("truncated blob")
        out.append(bytes(data[off: off + ln]))
        off += ln
    if off != len(data):
        raise FrameError(f"{len(data) - off} trailing bytes after {n} blobs")
    return out


# ---------------------------------------------------------------------------
# codec registry — the quantizer registry as a transport codec

# eligibility mirrors core/quantize.py: only float leaves with ndim >= 2
# and size >= _MIN_SIZE are binarized; everything else rides fp32
_MIN_SIZE = 32


def _binarizable(t: np.ndarray) -> bool:
    return np.issubdtype(t.dtype, np.floating) and t.ndim >= 2 and t.size >= _MIN_SIZE


class Codec:
    """Encodes a flat leaf list to bytes and back, with exact accounting.

    ``decode`` needs the leaf templates (shapes/dtypes of the current
    globals) — the stream itself carries no shape info, which keeps
    ``len(encode(leaves)) == nbytes(templates)`` an exact identity.
    """

    name: str = ""
    codec_id: int = -1

    def encode(self, leaves: Sequence[np.ndarray]) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, templates: Sequence[np.ndarray]) -> List[np.ndarray]:
        raise NotImplementedError

    def nbytes(self, templates: Sequence[np.ndarray]) -> int:
        raise NotImplementedError


class Fp32Codec(Codec):
    """Exact: raw little-endian bytes per leaf. The degenerate codec —
    distributed + fp32 + zero faults is bit-identical to ``sync``."""

    name, codec_id = "fp32", 0

    def encode(self, leaves):
        return b"".join(np.ascontiguousarray(l).tobytes() for l in leaves)

    def decode(self, data, templates):
        out, off = [], 0
        for t in templates:
            t = np.asarray(t)
            n = t.size * t.dtype.itemsize
            if off + n > len(data):
                raise FrameError("fp32 blob shorter than templates")
            out.append(np.frombuffer(data, dtype=t.dtype, count=t.size,
                                     offset=off).reshape(t.shape).copy())
            off += n
        if off != len(data):
            raise FrameError("fp32 blob longer than templates")
        return out

    def nbytes(self, templates):
        return sum(np.asarray(t).size * np.asarray(t).dtype.itemsize for t in templates)


class BinarizeCodec(Codec):
    """sign * mean|w| one-bit codec; bytes match comm_bytes(binarized=True).

    Eligible leaves (float, ndim>=2, size>=32) ship one fp32 scale + one
    sign bit per weight; the rest ride fp32.  Decoded values are
    +-scale (an exact zero decodes as +scale — one bit has no zero).
    """

    name, codec_id = "binarize", 1

    def encode(self, leaves):
        parts = []
        for l in leaves:
            l = np.ascontiguousarray(l)
            if _binarizable(l):
                scale = np.float32(np.mean(np.abs(l)))
                bits = np.packbits((l >= 0).reshape(-1))
                parts.append(_F32.pack(float(scale)) + bits.tobytes())
            else:
                parts.append(l.tobytes())
        return b"".join(parts)

    def decode(self, data, templates):
        out, off = [], 0
        for t in templates:
            t = np.asarray(t)
            if _binarizable(t):
                (scale,) = _F32.unpack_from(data, off)
                off += _F32.size
                nb = (t.size + 7) // 8
                bits = np.unpackbits(
                    np.frombuffer(data, dtype=np.uint8, count=nb, offset=off),
                    count=t.size)
                off += nb
                vals = np.where(bits.astype(bool), scale, -scale)
                out.append(vals.astype(t.dtype).reshape(t.shape))
            else:
                n = t.size * t.dtype.itemsize
                out.append(np.frombuffer(data, dtype=t.dtype, count=t.size,
                                         offset=off).reshape(t.shape).copy())
                off += n
        if off != len(data):
            raise FrameError("binarize blob length mismatch")
        return out

    def nbytes(self, templates):
        total = 0
        for t in templates:
            t = np.asarray(t)
            if _binarizable(t):
                total += (t.size + 7) // 8 + 4  # packed bits + fp32 scale
            else:
                total += t.size * t.dtype.itemsize
        return total


class Int8Codec(Codec):
    """Low-bit absmax codec: int8 values + one fp32 scale per float leaf
    (the LogitBank int8-row layout applied to params). ~3.99x vs fp32."""

    name, codec_id = "int8", 2

    def encode(self, leaves):
        parts = []
        for l in leaves:
            l = np.ascontiguousarray(l)
            if np.issubdtype(l.dtype, np.floating):
                absmax = float(np.max(np.abs(l))) if l.size else 0.0
                scale = np.float32(absmax / 127.0) if absmax > 0 else np.float32(1.0)
                q = np.clip(np.rint(l / scale), -127, 127).astype(np.int8)
                parts.append(_F32.pack(float(scale)) + q.tobytes())
            else:
                parts.append(l.tobytes())
        return b"".join(parts)

    def decode(self, data, templates):
        out, off = [], 0
        for t in templates:
            t = np.asarray(t)
            if np.issubdtype(t.dtype, np.floating):
                (scale,) = _F32.unpack_from(data, off)
                off += _F32.size
                q = np.frombuffer(data, dtype=np.int8, count=t.size, offset=off)
                off += t.size
                out.append((q.astype(t.dtype) * t.dtype.type(scale)).reshape(t.shape))
            else:
                n = t.size * t.dtype.itemsize
                out.append(np.frombuffer(data, dtype=t.dtype, count=t.size,
                                         offset=off).reshape(t.shape).copy())
                off += n
        if off != len(data):
            raise FrameError("int8 blob length mismatch")
        return out

    def nbytes(self, templates):
        total = 0
        for t in templates:
            t = np.asarray(t)
            if np.issubdtype(t.dtype, np.floating):
                total += t.size + 4  # int8 values + fp32 scale
            else:
                total += t.size * t.dtype.itemsize
        return total


_CODECS: Dict[str, Codec] = {}
_BY_ID: Dict[int, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    if codec.name in _CODECS:
        raise ValueError(f"wire codec {codec.name!r} already registered")
    if codec.codec_id in _BY_ID:
        raise ValueError(f"wire codec id {codec.codec_id} already registered")
    _CODECS[codec.name] = codec
    _BY_ID[codec.codec_id] = codec
    return codec


def get_codec(name: str) -> Codec:
    if name not in _CODECS:
        raise KeyError(f"unknown wire codec {name!r}; have {available_codecs()}")
    return _CODECS[name]


def codec_by_id(codec_id: int) -> Codec:
    if codec_id not in _BY_ID:
        raise FrameError(f"unknown wire codec id {codec_id}")
    return _BY_ID[codec_id]


def available_codecs() -> List[str]:
    return sorted(_CODECS)


register_codec(Fp32Codec())
register_codec(BinarizeCodec())
register_codec(Int8Codec())


# ---------------------------------------------------------------------------
# wire log: append-only record of accepted UPLOAD frames, replayed on
# fusion-pod restart so in-flight work is not re-dispatched


class WireLog:
    def __init__(self, path: str):
        self.path = path

    def append(self, frame_bytes: bytes) -> None:
        from repro.checkpoint.io import append_record

        append_record(self.path, frame_bytes)

    def replay(self, round_: int) -> List[Frame]:
        """Decoded UPLOAD frames of ``round_``; skips undecodable records
        (a torn tail from a crash mid-append is expected, not fatal)."""
        from repro.checkpoint.io import read_records

        out = []
        for rec in read_records(self.path):
            try:
                f = decode_frame(rec)
            except FrameError:
                continue
            if f.kind == UPLOAD and f.round == round_:
                out.append(f)
        return out
